"""Parse ``jax.profiler`` traces into per-level BFS phase timings.

The roofline rows in ``BFSPlan.describe()`` *predict* where a level's
time goes (collective wire vs fused-tail compute); this module supplies
the *measured* half: it loads the chrome-trace JSON the profiler writes
(``<logdir>/plugins/profile/<ts>/*.trace.json.gz``), keeps the compiled
XLA op events (the ones carrying an ``args.hlo_op`` attribution — python
frame and runtime bookkeeping events are dropped), classifies each op
into a traversal phase by its HLO op name, and splits the run into
levels by clustering the collective events the level loop must issue
once per iteration.

Phases (the per-level critical path ISSUE 9 shortens):

  * ``collective``   — all-to-all / all-gather / all-reduce /
    reduce-scatter / collective-permute instructions;
  * ``expand``       — the edge-walk half: gather/scatter/iota fusions
    that read edge endpoints and build candidate masks;
  * ``fold``         — word-level merge work: or/and/shift fusions over
    the received packed candidate words;
  * ``owner_update`` — the dist tail: compare/select fusions that test
    candidates against INF and write depths (fused plans collapse fold +
    owner_update into one kernel, so their combined share is what the
    fused-vs-unfused benchmark compares);
  * ``other``        — loop plumbing (while/condition overhead, copies).

Used three ways: the ``--profile`` flag of the launchers prints a phase
summary after the run, the latency benchmark validates the describe()
roofline against measured phase times, and a unit test parses a
checked-in synthetic trace so the format assumptions fail loudly if a
jax upgrade moves the cheese.
"""

from __future__ import annotations

import contextlib
import glob
import gzip
import json
import os
import re
from dataclasses import dataclass, field
from typing import List, Optional

_COLLECTIVE_RE = re.compile(
    r"all-to-all|all-gather|all-reduce|reduce-scatter|collective-permute")
_EXPAND_RE = re.compile(r"gather|scatter|iota|dynamic-slice|dynamic_slice")
_FOLD_RE = re.compile(r"\bor\b|_or_|^or[._]|shift|\band\b|_and_|^and[._]"
                      r"|bitcast|pack|concatenate")
_UPDATE_RE = re.compile(r"select|compare|broadcast|convert|minimum|maximum"
                        r"|add|multiply")
# host-side python frames are prefixed "$" in jax's chrome traces; other
# non-op events (runtime threads, XLA metadata) simply lack args.hlo_op
_PY_FRAME = "$"
# container ops whose event spans *include* their children — keeping them
# would double-count every op inside the level loop
_CONTAINER_RE = re.compile(r"^(while|call|conditional)\b")

PHASES = ("expand", "collective", "fold", "owner_update", "other")


def classify(hlo_op: str) -> str:
    """Map one HLO op name (e.g. ``add_select_fusion``) to a phase."""
    name = hlo_op.lower()
    if _COLLECTIVE_RE.search(name):
        return "collective"
    if _EXPAND_RE.search(name):
        return "expand"
    if _FOLD_RE.search(name):
        return "fold"
    if _UPDATE_RE.search(name):
        return "owner_update"
    return "other"


@dataclass
class TraceOp:
    """One compiled-XLA-op event: name, phase, start + duration (s)."""

    hlo_op: str
    phase: str
    ts: float
    dur: float


@dataclass
class PhaseTimings:
    """Per-phase device-time totals, optionally split per level."""

    total_s: dict                      # phase -> summed seconds
    counts: dict                       # phase -> event count
    levels: List[dict] = field(default_factory=list)  # per-level totals
    span_s: float = 0.0                # first-op start to last-op end
    n_ops: int = 0

    def to_dict(self) -> dict:
        return {"total_s": self.total_s, "counts": self.counts,
                "levels": self.levels, "span_s": self.span_s,
                "n_ops": self.n_ops}


def find_trace_file(path: str) -> str:
    """Resolve a profiler log dir (or a direct file) to one trace json.

    ``jax.profiler.stop_trace`` writes
    ``<logdir>/plugins/profile/<timestamp>/<host>.trace.json.gz``; accept
    the logdir, the timestamp dir, or the file itself, and prefer the
    newest chrome trace over the perfetto protobuf variants.
    """
    if os.path.isfile(path):
        return path
    pats = (os.path.join(path, "*.trace.json.gz"),
            os.path.join(path, "plugins", "profile", "*", "*.trace.json.gz"),
            os.path.join(path, "*", "*.trace.json.gz"))
    hits = [h for pat in pats for h in glob.glob(pat)]
    if not hits:
        raise FileNotFoundError(
            f"no *.trace.json.gz under {path!r} — was the run launched "
            "with --profile (jax.profiler.start_trace)?")
    return max(hits, key=os.path.getmtime)


def load_events(path: str) -> List[TraceOp]:
    """Load + filter one trace file into classified XLA op events."""
    path = find_trace_file(path)
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        raw = json.load(f)
    ops: List[TraceOp] = []
    for ev in raw.get("traceEvents", ()):
        if ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        hlo_op = args.get("hlo_op")
        name = ev.get("name", "")
        if not hlo_op or name.startswith(_PY_FRAME):
            continue
        if _CONTAINER_RE.match(hlo_op):
            continue
        # chrome-trace ts/dur are microseconds regardless of
        # displayTimeUnit (that key only styles the viewer)
        ops.append(TraceOp(hlo_op=hlo_op, phase=classify(hlo_op),
                           ts=ev.get("ts", 0) * 1e-6,
                           dur=ev.get("dur", 0) * 1e-6))
    ops.sort(key=lambda o: o.ts)
    return ops


def split_levels(ops: List[TraceOp],
                 n_levels: Optional[int] = None) -> List[List[TraceOp]]:
    """Segment a run's ops into per-level groups.

    Every level iteration issues at least one payload collective, so
    collective start times cluster per level.  With ``n_levels`` known
    (the benchmark reads it off ``BFSRunStats``) the split cuts at the
    ``n_levels - 1`` largest gaps between consecutive collective starts
    — robust to any per-level op mix.  Without it, cut at gaps larger
    than 2x the median spacing (degrades to one segment when fewer than
    two collectives are visible).
    """
    colls = [op for op in ops if op.phase == "collective"]
    if len(colls) < 2 or (n_levels is not None and n_levels <= 1):
        return [ops] if ops else []
    gaps = [(colls[i + 1].ts - colls[i].ts, i) for i in range(len(colls) - 1)]
    if n_levels is not None:
        cut_idx = sorted(i for _, i in
                         sorted(gaps, reverse=True)[: n_levels - 1])
    else:
        med = sorted(g for g, _ in gaps)[len(gaps) // 2]
        cut_idx = [i for g, i in gaps if g > 2 * med and med > 0]
    # boundary timestamps: halfway into each cut gap
    bounds = [(colls[i].ts + colls[i].dur + colls[i + 1].ts) / 2
              for i in cut_idx]
    segments: List[List[TraceOp]] = [[] for _ in range(len(bounds) + 1)]
    for op in ops:
        k = sum(1 for b in bounds if op.ts >= b)
        segments[k].append(op)
    return [seg for seg in segments if seg]


def phase_timings(ops: List[TraceOp],
                  n_levels: Optional[int] = None) -> PhaseTimings:
    """Aggregate classified ops into per-phase (and per-level) seconds."""
    total = {ph: 0.0 for ph in PHASES}
    counts = {ph: 0 for ph in PHASES}
    for op in ops:
        total[op.phase] += op.dur
        counts[op.phase] += 1
    levels = []
    for seg in split_levels(ops, n_levels):
        lv = {ph: 0.0 for ph in PHASES}
        for op in seg:
            lv[op.phase] += op.dur
        levels.append(lv)
    span = (max(op.ts + op.dur for op in ops) - min(op.ts for op in ops)
            if ops else 0.0)
    return PhaseTimings(total_s=total, counts=counts, levels=levels,
                        span_s=span, n_ops=len(ops))


def parse_trace(path: str, n_levels: Optional[int] = None) -> PhaseTimings:
    """One-call helper: resolve, load, classify, aggregate."""
    return phase_timings(load_events(path), n_levels=n_levels)


@contextlib.contextmanager
def capture(logdir: str):
    """Profile the enclosed block into ``logdir`` (the --profile flag).

    Thin wrapper over ``jax.profiler.start_trace``/``stop_trace`` so the
    launchers share one spelling; the chrome trace lands where
    ``find_trace_file(logdir)`` picks it up.
    """
    import jax

    os.makedirs(logdir, exist_ok=True)
    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def format_summary(t: PhaseTimings) -> str:
    """Render one PhaseTimings as the launchers' post-run report."""
    tot = sum(t.total_s.values()) or 1.0
    rows = [f"trace: {t.n_ops} XLA op events over {t.span_s * 1e3:.1f}ms "
            f"wall, {len(t.levels)} level segment(s)"]
    for ph in PHASES:
        s = t.total_s[ph]
        rows.append(f"  {ph:<13} {s * 1e3:9.3f}ms  {s / tot:6.1%}  "
                    f"({t.counts[ph]} ops)")
    return "\n".join(rows)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="summarize a jax profiler trace into BFS phase "
                    "timings (expand / collective / fold / owner_update)")
    ap.add_argument("path", help="profiler logdir or *.trace.json.gz file")
    ap.add_argument("--levels", type=int, default=None,
                    help="known level count (cuts at the N-1 largest "
                         "collective gaps); default: median-gap heuristic")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable timing dict")
    args = ap.parse_args(argv)
    t = parse_trace(args.path, n_levels=args.levels)
    if args.json:
        print(json.dumps(t.to_dict(), indent=2, sort_keys=True))
    else:
        print(format_summary(t))
        for i, lv in enumerate(t.levels):
            tot = sum(lv.values()) or 1.0
            share = "  ".join(f"{ph}={lv[ph] / tot:.0%}" for ph in PHASES
                              if lv[ph] > 0)
            print(f"  level[{i}] {tot * 1e3:8.3f}ms  {share}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
