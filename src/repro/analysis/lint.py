"""AST lints for repo conventions (rules RX001-RX005).

Three families of invariants the exchange registry and compiled loop
depend on, enforced statically over ``src/repro``:

* **Registry discipline** — every ``register_exchange(kind, name,
  bytes_model, wire=...)`` call pairs a byte model with the signature
  its kind demands (RX001) and the model is pure host Python: plan-time
  pricing must never touch ``jnp``/``jax``/``lax`` (RX002).  Byte-model
  signatures per kind::

      dense / expand_row / fold_col          (n|_, ..., itemsize, ...)  5 args
      queue                                  (p, cap, itemsize, density=)  4
      expand_row_sparse / fold_col_sparse    (r, c, cap, itemsize, density=)  5

* **Twin coverage** — every bytes-tier strategy has its cheaper wire
  twin registered: ``<name>_packed`` for dense kinds,
  ``<name>_compressed`` for sparse kinds (RX003), so
  ``wire_format="auto"`` always has both tiers to price.

* **Compiled-loop hygiene** — inside the modules whose code runs under
  ``lax.while_loop`` (``core/bfs.py``, ``core/frontier.py``), no Python
  ``if`` branches on a traced ``jnp``/``lax`` expression (RX004 — it
  would either retrace per value or raise a ConcretizationTypeError
  mid-flight) and no host clock calls (RX005 — ``time.time()`` under a
  trace timestamps tracing, not execution).

False positives are silenced inline with a reasoned suppression::

    # audit: allow(RX003) -- hierarchical is itself the packed tier

The reason string after ``--`` is mandatory: a bare ``allow`` is itself
a violation (SUP001).  A suppression comment matches on its own line,
the line above the flagged statement, or the flagged statement's line.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.report import AuditReport

# byte-model signature per kind: (positional arity, trailing kwarg that
# must carry a default — the density knob of the sparse tiers)
MODEL_SPEC: Dict[str, Tuple[int, Optional[str]]] = {
    "dense": (5, None),
    "expand_row": (5, None),
    "fold_col": (5, None),
    "queue": (4, "density"),
    "expand_row_sparse": (5, "density"),
    "fold_col_sparse": (5, "density"),
}
DENSE_KINDS = ("dense", "expand_row", "fold_col")
SPARSE_KINDS = ("queue", "expand_row_sparse", "fold_col_sparse")
TRACED_MODULES = ("core/bfs.py", "core/frontier.py")
_CLOCK_CALLS = {("time", "time"), ("time", "perf_counter"),
                ("time", "monotonic"), ("time", "process_time")}

_ALLOW_RE = re.compile(
    r"#\s*audit:\s*allow\(([A-Z]{2,3}[0-9]{3})\)(?:\s*--\s*(.*\S))?")


class Suppressions:
    """Inline ``# audit: allow(RULE) -- reason`` comments of one file."""

    def __init__(self, src: str, path: str,
                 report: Optional[AuditReport] = None):
        self.by_line: Dict[int, Tuple[str, str]] = {}
        for i, line in enumerate(src.splitlines(), 1):
            m = _ALLOW_RE.search(line)
            if not m:
                continue
            rule, reason = m.group(1), m.group(2)
            if not reason:
                if report is not None:
                    report.add("SUP001",
                               f"allow({rule}) without a `-- reason`",
                               file=path, line=i)
                continue
            self.by_line[i] = (rule, reason)

    def reason(self, rule: str, *lines: int) -> Optional[str]:
        """Suppression reason if any candidate line allows ``rule``."""
        for ln in lines:
            ent = self.by_line.get(ln)
            if ent and ent[0] == rule:
                return ent[1]
        return None


def _flag(report: AuditReport, sup: Suppressions, rule: str, message: str,
          path: str, line: int, extra_lines: Tuple[int, ...] = ()) -> None:
    reason = sup.reason(rule, line, line - 1, *extra_lines)
    report.add(rule, message, file=path, line=line,
               suppressed=reason is not None,
               suppress_reason=reason or "")


def _references(tree: ast.AST, names: Tuple[str, ...]) -> Optional[ast.AST]:
    """First node under ``tree`` naming one of ``names`` (jnp/lax/...)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id in names:
            return node
    return None


def _model_def(module: ast.Module, expr: ast.AST):
    """Resolve a register_exchange byte-model argument to its function.

    Returns the FunctionDef/Lambda, or None when the expression is
    dynamic (attribute chains, calls) and can't be checked statically.
    """
    if isinstance(expr, ast.Lambda):
        return expr
    if not isinstance(expr, ast.Name):
        return None
    for node in module.body:
        if isinstance(node, ast.FunctionDef) and node.name == expr.id:
            return node
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == expr.id \
                        and isinstance(node.value, ast.Lambda):
                    return node.value
    return None


def _check_model(report: AuditReport, sup: Suppressions, path: str,
                 call: ast.Call, kind: str, name: str,
                 model) -> None:
    spec = MODEL_SPEC.get(kind)
    if spec is None or model is None:
        return
    arity, tail = spec
    args = model.args
    n_pos = len(args.args) + len(args.posonlyargs)
    lines = (call.lineno,)
    if n_pos != arity:
        _flag(report, sup, "RX001",
              f"byte model for ({kind!r}, {name!r}) takes {n_pos} "
              f"positional args, kind expects {arity}",
              path, call.lineno, lines)
        return
    if tail is not None:
        last = (args.posonlyargs + args.args)[-1]
        if last.arg != tail or not args.defaults:
            _flag(report, sup, "RX001",
                  f"byte model for ({kind!r}, {name!r}) must end with "
                  f"a defaulted `{tail}=` parameter",
                  path, call.lineno, lines)
    body = model.body if isinstance(model, ast.Lambda) else model
    traced = _references(body, ("jnp", "jax", "lax"))
    if traced is not None:
        _flag(report, sup, "RX002",
              f"byte model for ({kind!r}, {name!r}) references "
              "jnp/jax/lax — plan-time pricing must be pure Python",
              path, getattr(traced, "lineno", call.lineno),
              (call.lineno, getattr(model, "lineno", call.lineno)))


def _registrations(module: ast.Module):
    """Every register_exchange call: (call, kind, name, model_expr, wire)."""
    for node in ast.walk(module):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        fn_name = fn.id if isinstance(fn, ast.Name) else \
            fn.attr if isinstance(fn, ast.Attribute) else ""
        if fn_name != "register_exchange" or len(node.args) < 2:
            continue
        kind = node.args[0].value \
            if isinstance(node.args[0], ast.Constant) else None
        name = node.args[1].value \
            if isinstance(node.args[1], ast.Constant) else None
        model_expr = node.args[2] if len(node.args) > 2 else None
        for kw in node.keywords:
            if kw.arg == "bytes_model":
                model_expr = kw.value
        wire = "bytes"
        for kw in node.keywords:
            if kw.arg == "wire" and isinstance(kw.value, ast.Constant):
                wire = kw.value.value
        if kind is None or name is None:
            continue
        yield node, kind, name, model_expr, wire


def _lint_traced_module(report: AuditReport, sup: Suppressions,
                        path: str, module: ast.Module) -> None:
    for node in ast.walk(module):
        if isinstance(node, ast.If):
            hit = _references(node.test, ("jnp", "lax"))
            if hit is not None:
                _flag(report, sup, "RX004",
                      "Python `if` over a jnp/lax expression — use "
                      "lax.cond / jnp.where in compiled-loop code",
                      path, node.lineno)
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            base = node.func.value
            if isinstance(base, ast.Name) and \
                    (base.id, node.func.attr) in _CLOCK_CALLS:
                _flag(report, sup, "RX005",
                      f"{base.id}.{node.func.attr}() inside a "
                      "compiled-loop module — host clocks read trace "
                      "time, not run time",
                      path, node.lineno)


def lint_sources(sources: Dict[str, str],
                 name: str = "lint") -> AuditReport:
    """Lint a {path: source} mapping (the unit-testable entry point)."""
    report = AuditReport(name)
    regs: List[Tuple[str, str, str, str, int]] = []
    for path, src in sorted(sources.items()):
        sup = Suppressions(src, path, report)
        try:
            module = ast.parse(src)
        except SyntaxError as e:
            report.add("RX001", f"unparseable module: {e}", file=path,
                       line=e.lineno or 0)
            continue
        for call, kind, sname, model_expr, wire in _registrations(module):
            if model_expr is None:
                _flag(report, sup, "RX001",
                      f"register_exchange({kind!r}, {sname!r}) has no "
                      "byte model", path, call.lineno)
                continue
            model = _model_def(module, model_expr)
            _check_model(report, sup, path, call, kind, sname, model)
            regs.append((kind, sname, wire, path, call.lineno))
        norm = path.replace(os.sep, "/")
        if any(norm.endswith(m) for m in TRACED_MODULES):
            _lint_traced_module(report, sup, path, module)

    registered = {(k, n) for k, n, _, _, _ in regs}
    sup_by_path = {path: Suppressions(src, path)
                   for path, src in sources.items()}
    for kind, sname, wire, path, line in regs:
        if wire != "bytes":
            continue
        twin = sname + ("_packed" if kind in DENSE_KINDS else "_compressed")
        if (kind, twin) not in registered:
            _flag(report, sup_by_path[path], "RX003",
                  f"bytes-tier strategy ({kind!r}, {sname!r}) has no "
                  f"({kind!r}, {twin!r}) twin — wire_format='auto' "
                  "cannot price the cheaper tier", path, line)
    report.info["registrations"] = [
        {"kind": k, "name": n, "wire": w, "file": p, "line": ln}
        for k, n, w, p, ln in regs]
    return report


def repo_root() -> str:
    import repro
    return os.path.dirname(os.path.abspath(repro.__file__))


def lint_tree(root: Optional[str] = None) -> AuditReport:
    """Lint every module under ``src/repro`` (CI / CLI entry point)."""
    root = root or repo_root()
    sources: Dict[str, str] = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                sources[os.path.relpath(path, os.path.dirname(root))] = \
                    f.read()
    return lint_sources(sources, name="lint:src/repro")
