"""Jit'd public wrappers around the block-sparse SpMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm.kernel import DEFAULT_BLOCK, bsr_spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm(blocks, block_rows, block_cols, x, *, n_rows_pad,
         block: int = DEFAULT_BLOCK, interpret: bool | None = None):
    """Block-sparse A @ X. Uses the Pallas kernel (interpret mode off-TPU)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return bsr_spmm(blocks, block_rows, block_cols, x, n_rows_pad=n_rows_pad,
                    block=block, interpret=interp)


def frontier_expand(blocks, block_rows, block_cols, frontier, *, n_rows_pad,
                    block: int = DEFAULT_BLOCK, interpret: bool | None = None):
    """Batched BFS frontier expansion: (A @ F) > 0 over the MXU.

    frontier: (n_cols_pad, S) uint8 — S simultaneous sources.  For S < 128
    the lane dimension is padded; batching sources to a multiple of 128 is
    what makes the TPU formulation profitable (DESIGN.md).
    """
    y = spmm(blocks, block_rows, block_cols, frontier.astype(jnp.float32),
             n_rows_pad=n_rows_pad, block=block, interpret=interpret)
    return (y > 0).astype(jnp.uint8)


def spmm_reference(blocks, block_rows, block_cols, x, *, n_rows_pad):
    return bsr_spmm_ref(blocks, block_rows, block_cols, x,
                        n_rows_pad=n_rows_pad)
