"""Jit'd public wrappers around the block-sparse SpMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm.kernel import (DEFAULT_BLOCK, bitpack_words,
                                           bsr_spmm)
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm(blocks, block_rows, block_cols, x, *, n_rows_pad,
         block: int = DEFAULT_BLOCK, interpret: bool | None = None):
    """Block-sparse A @ X. Uses the Pallas kernel (interpret mode off-TPU)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return bsr_spmm(blocks, block_rows, block_cols, x, n_rows_pad=n_rows_pad,
                    block=block, interpret=interp)


def frontier_expand(blocks, block_rows, block_cols, frontier, *, n_rows_pad,
                    block: int = DEFAULT_BLOCK, interpret: bool | None = None):
    """Batched BFS frontier expansion: (A @ F) > 0 over the MXU.

    frontier: (n_cols_pad, S) uint8 — S simultaneous sources.  For S < 128
    the lane dimension is padded; batching sources to a multiple of 128 is
    what makes the TPU formulation profitable (DESIGN.md).
    """
    y = spmm(blocks, block_rows, block_cols, frontier.astype(jnp.float32),
             n_rows_pad=n_rows_pad, block=block, interpret=interpret)
    return (y > 0).astype(jnp.uint8)


def frontier_expand_packed(blocks, block_rows, block_cols, frontier, *,
                           n_rows_pad, n_valid, n_blocks,
                           block: int = DEFAULT_BLOCK,
                           interpret: bool | None = None):
    """Kernel expansion emitting *packed* candidate words.

    Runs the bsr_spmm expansion, then packs the boolean candidates into
    the per-owner-blocked uint32 bitset layout the packed dense exchange
    ships (``n_blocks`` segments of ``n_valid / n_blocks`` bits, each
    padded to whole words — ``frontier.pack_bits`` semantics).  When the
    segment size is word-aligned the pack itself runs as the Pallas
    ``bitpack_words`` kernel (blocked == flat packing in that case); an
    unaligned segment falls back to the jnp pack, fused into the same
    jit.  Returns ``(n_blocks * ceil(seg/32), S)`` uint32.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    y = spmm(blocks, block_rows, block_cols,
             frontier.astype(jnp.float32), n_rows_pad=n_rows_pad,
             block=block, interpret=interp)
    seg = n_valid // n_blocks
    assert seg * n_blocks == n_valid, (n_valid, n_blocks)
    if seg % 32 == 0:
        return bitpack_words(y[:n_valid], interpret=interp)
    from repro.core.frontier import pack_bits
    return pack_bits((y[:n_valid] > 0).astype(jnp.uint8), n_blocks)


def spmm_reference(blocks, block_rows, block_cols, x, *, n_rows_pad):
    return bsr_spmm_ref(blocks, block_rows, block_cols, x,
                        n_rows_pad=n_rows_pad)
