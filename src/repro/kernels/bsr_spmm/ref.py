"""Pure-jnp oracle for the block-sparse SpMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bsr_spmm_ref(blocks: jnp.ndarray, block_rows: jnp.ndarray,
                 block_cols: jnp.ndarray, x: jnp.ndarray, *,
                 n_rows_pad: int) -> jnp.ndarray:
    """Dense-per-block einsum + segment-sum scatter. O(K·B·d) memory."""
    k, b, _ = blocks.shape
    n, d = x.shape
    xb = x.reshape(n // b, b, d)
    contrib = jnp.einsum("kab,kbd->kad", blocks.astype(jnp.float32),
                         xb[block_cols].astype(jnp.float32))
    y = jax.ops.segment_sum(contrib, block_rows,
                            num_segments=n_rows_pad // b)
    return y.reshape(n_rows_pad, d)


def frontier_expand_ref(blocks, block_rows, block_cols, frontier, *,
                        n_rows_pad):
    """Boolean-semiring BFS expansion oracle: candidates = (A @ F) > 0."""
    y = bsr_spmm_ref(blocks, block_rows, block_cols,
                     frontier.astype(jnp.float32), n_rows_pad=n_rows_pad)
    return (y > 0).astype(jnp.uint8)
