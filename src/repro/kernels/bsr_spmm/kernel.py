"""Block-sparse SpMM Pallas TPU kernel — the BFS/GNN expansion hot loop.

TPU adaptation of the paper's per-vertex frontier expansion (DESIGN.md
§Hardware-adaptation): instead of the GPU/CPU idiom of per-thread neighbor
queues (paper fig. 2 lines 13-16), the adjacency is stored as block-CSR
(only nonempty 128x128 tiles materialized, sorted by block-row) and one BFS
level for a *batch* of S sources is the boolean-semiring product

    Y[n, S] = A[n, n] @ F[n, S]   (candidates = Y > 0)

which runs on the MXU at full tile alignment.  The same kernel with plain
sum semantics is the SpMM ``Ã·X`` of GCN-family GNNs (kernel_taxonomy §B.3).

Pallas specifics:
  * block indices arrive via ``PrefetchScalarGridSpec`` (scalar prefetch),
    so the data-dependent tile schedule is resolved in SMEM before each
    grid step — the standard Pallas block-sparse pattern.
  * grid is (d_tiles, K) with K fastest: for a fixed feature tile j, all
    blocks of one block-row are consecutive, so the output tile (row, j)
    is revisited contiguously and accumulates in VMEM; it is zeroed on
    first visit (``row_changed``) and flushed automatically on the last.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

DEFAULT_BLOCK = 128


def _spmm_kernel(br_ref, bc_ref, blocks_ref, x_ref, y_ref):
    """One grid step: y[br[k], j] += blocks[k] @ x[bc[k], j]."""
    k = pl.program_id(1)

    # Zero the accumulator on the first visit of this output tile: either
    # the very first block, or the block-row just changed.
    row_changed = jnp.where(k == 0, True, br_ref[k] != br_ref[jnp.maximum(k - 1, 0)])

    @pl.when(row_changed)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = blocks_ref[0]          # (B, B)
    x = x_ref[...]             # (B, dt)
    y_ref[...] += jnp.dot(a, x.astype(a.dtype),
                          preferred_element_type=y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows_pad", "block", "d_tile", "interpret"))
def bsr_spmm(blocks: jnp.ndarray, block_rows: jnp.ndarray,
             block_cols: jnp.ndarray, x: jnp.ndarray, *, n_rows_pad: int,
             block: int = DEFAULT_BLOCK, d_tile: int = DEFAULT_BLOCK,
             interpret: bool = True) -> jnp.ndarray:
    """Y = A @ X with A in block-CSR (blocks sorted by block_rows).

    blocks: (K, B, B) tile values; block_rows/block_cols: (K,) int32;
    x: (n_cols_pad, d).  Returns (n_rows_pad, d) f32.
    """
    k_blocks, b0, b1 = blocks.shape
    assert b0 == b1 == block, (blocks.shape, block)
    n, d = x.shape
    assert n % block == 0 and n_rows_pad % block == 0
    d_pad = -(-d // d_tile) * d_tile
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
    d_tiles = d_pad // d_tile

    grid = (d_tiles, k_blocks)
    out = pl.pallas_call(
        _spmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_rows, block_cols
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block, block),
                             lambda j, k, br, bc: (k, 0, 0)),
                pl.BlockSpec((block, d_tile),
                             lambda j, k, br, bc: (bc[k], j)),
            ],
            out_specs=pl.BlockSpec((block, d_tile),
                                   lambda j, k, br, bc: (br[k], j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_rows_pad, d_pad), jnp.float32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(block_rows, block_cols, blocks, x)
    return out[:, :d]


def _bitpack_kernel(x_ref, out_ref):
    """One grid step: fold a (32, S) 0/1 tile into one (1, S) uint32 word
    row — bit ``i`` of the word is row ``i`` of the tile (LSB-first, the
    ``frontier.pack_bits`` layout)."""
    bits = (x_ref[...] > 0).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out_ref[...] = (bits << shifts[:, None]).sum(
        axis=0, dtype=jnp.uint32)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitpack_words(mask: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Pack a ``(32*W, S)`` candidate mask into ``(W, S)`` uint32 words on
    device — the packed-wire emission of the kernel expansion path.

    The row count must be 32-aligned (the bsr_spmm output rows are padded
    to 128, so a whole-output pack always is); unaligned *segmented*
    packing falls back to ``frontier.pack_bits`` in the ops wrapper.
    """
    m, s = mask.shape
    assert m % 32 == 0, m
    w = m // 32
    return pl.pallas_call(
        _bitpack_kernel,
        grid=(w,),
        in_specs=[pl.BlockSpec((32, s), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w, s), jnp.uint32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(mask)
