"""Fused fold-merge + owner-update tail of a dense BFS level.

After the dense (1-D) or fold (2-D) collective, the unfused level tail is
three separate XLA ops serialized on the critical path:

    own  = frontier.unpack_bits(words, m)      # (m, S) uint8 materialized
    new  = (own > 0) & (dist == INF)           # (m, S) bool materialized
    dist = where(new, level, dist)

plus a fourth — ``pack_bits(new)`` — when the *next* level's expand-phase
collective wants packed words again.  This module fuses all of them into
one pass over the received candidate words: each uint32 word is bit-tested
directly against 32 rows of ``dist``, depths are written, and the next
frontier is emitted **both** as the byte mask the queue/stats paths read
and as packed words ready for the next level's collective — the
double-buffered frontier generation that lets XLA issue the expand
collective of level L+1 before the owner-update scatter of level L
retires (ISSUE 9 / ROADMAP "Profile-driven latency hiding").

Two implementations behind one dispatcher, mirroring ``bsr_spmm.ops``:

* ``_fold_update_pallas`` — the TPU kernel: grid ``(W,)``, one (32, S)
  dist tile + one (1, S) word row per step, level via scalar prefetch.
* ``_fold_update_jnp`` — a single fused jnp expression for non-TPU
  backends.  Unlike ``bsr_spmm`` we do *not* run the Pallas kernel in
  interpret mode on the engine hot path: interpret mode executes the
  grid as a host loop, which for W = shard/32 grid steps would swamp the
  very tail latency this kernel exists to remove.  Tests force the
  Pallas path with ``use_pallas=True`` (interpret) on small shapes to
  keep both implementations bit-identical.

Layout contract (``frontier.pack_bits``): bit ``i`` of word ``w`` is
vertex ``w*32 + i`` (LSB-first); pad bits beyond ``m`` must be zero —
callers mask invalid vertices *before* the collective, so every set bit
is a genuine candidate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params
from repro.core.frontier import INF, packed_words

# Python-int mirror of frontier.INF: a closed-over jax array would trip
# pallas' captured-constant check inside the kernel body.
_INF = int(INF)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fold_update_kernel(level_ref, words_ref, dist_ref,
                        dist_out, new_out, words_out):
    """One grid step: bit-test one uint32 word row against 32 dist rows.

    Emits the updated dist tile, the new-vertex byte mask, and the new
    frontier re-packed as one word row (only newly discovered vertices
    carry into the next generation, so the output words are exactly
    ``pack_bits(new_mask)``).
    """
    lv = level_ref[0]
    d = dist_ref[...]                                # (32, S) int32
    w = words_ref[...]                               # (1, S) uint32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, 1), 0)
    bits = (w >> shifts) & jnp.uint32(1)             # (32, S)
    new = (bits > 0) & (d == _INF)
    dist_out[...] = jnp.where(new, lv, d)
    new_out[...] = new.astype(jnp.uint8)
    words_out[...] = (new.astype(jnp.uint32) << shifts).sum(
        axis=0, dtype=jnp.uint32)[None, :]


def _fold_update_pallas(words, dist, level, *, interpret: bool):
    w, s = words.shape
    m = dist.shape[0]
    pad = w * 32 - m
    if pad:
        # pad rows read INF but their word bits are zero, so new == 0 and
        # the padded dist rows round-trip untouched
        dist = jnp.pad(dist, ((0, pad), (0, 0)), constant_values=INF)
    level_arr = jnp.asarray(level, jnp.int32).reshape(1)
    dist2, new, new_words = pl.pallas_call(
        _fold_update_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,                   # level
            grid=(w,),
            in_specs=[
                pl.BlockSpec((1, s), lambda i, lv: (i, 0)),    # words
                pl.BlockSpec((32, s), lambda i, lv: (i, 0)),   # dist
            ],
            out_specs=[
                pl.BlockSpec((32, s), lambda i, lv: (i, 0)),   # dist'
                pl.BlockSpec((32, s), lambda i, lv: (i, 0)),   # new mask
                pl.BlockSpec((1, s), lambda i, lv: (i, 0)),    # new words
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((w * 32, s), jnp.int32),
            jax.ShapeDtypeStruct((w * 32, s), jnp.uint8),
            jax.ShapeDtypeStruct((w, s), jnp.uint32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(level_arr, words, dist)
    return dist2[:m], new[:m], new_words


def _fold_update_jnp(words, dist, level):
    """Fused tail as one jnp expression (non-TPU backends).

    A single elementwise chain over the (W, 32, S) bit view — XLA fuses
    the unpack-test-update-repack into one loop with no (m, S) uint8
    candidate array or standalone repack between the collective and the
    next level's expand.
    """
    w, s = words.shape
    m = dist.shape[0]
    pad = w * 32 - m
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None, :] >> shifts[None, :, None]) & jnp.uint32(1)
    bits = bits.reshape(w * 32, s)
    if pad:
        bits = bits[:m]
    new = (bits > 0) & (dist == INF)
    dist2 = jnp.where(new, jnp.int32(level), dist)
    nw = jnp.pad(new, ((0, pad), (0, 0))) if pad else new
    new_words = (nw.astype(jnp.uint32).reshape(w, 32, s)
                 << shifts[None, :, None]).sum(axis=1, dtype=jnp.uint32)
    return dist2, new.astype(jnp.uint8), new_words


def fold_update(words, dist, level, *, use_pallas: bool | None = None):
    """Fused dense-tail update: merge words into dist, emit next frontier.

    Args:
      words: ``(W, S)`` uint32 merged candidate words for this shard's
        owned vertex block, ``W == packed_words(m)``, pad bits zero.
      dist: ``(m, S)`` int32 depths (INF = undiscovered).
      level: scalar int32 depth to write for newly discovered vertices.
      use_pallas: force the Pallas kernel (interpret mode off-TPU; tests
        only) or the jnp path; default picks Pallas on TPU, jnp elsewhere.

    Returns ``(dist', new_mask, new_words)`` — updated ``(m, S)`` int32
    depths, the ``(m, S)`` uint8 newly-discovered mask, and the ``(W, S)``
    uint32 packed next-frontier words (``pack_bits(new_mask)``).
    """
    w, s = words.shape
    m = dist.shape[0]
    if w != packed_words(m):
        raise ValueError(f"words rows {w} != packed_words({m})="
                         f"{packed_words(m)}")
    if dist.shape[1] != s:
        raise ValueError(f"dist batch {dist.shape[1]} != words batch {s}")
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _fold_update_pallas(words, dist, level,
                                   interpret=not _on_tpu())
    return _fold_update_jnp(words, dist, level)
