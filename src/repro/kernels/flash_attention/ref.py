"""Pure-jnp attention oracle (materialized scores) with GQA/causal/window."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh). fp32 softmax."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)  # rows with no visible key -> all-zero
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.where(l == 0, 1.0, l),
                   v.astype(jnp.float32))
    return o.astype(q.dtype)
