"""Blocked causal GQA flash attention (forward) — Pallas TPU kernel.

IO-aware attention (FlashAttention, arXiv:2205.14135) adapted to the TPU
memory hierarchy: (Bq, Dh) query tiles stay resident in VMEM while (Bk, Dh)
key/value tiles stream HBM->VMEM; the online-softmax running max/sum and
the output accumulator live in VMEM scratch across the kv grid dimension.
Supports:
  * GQA — the kv-head index is derived from the q-head index inside the
    BlockSpec index maps (no materialized head repeat),
  * causal masking,
  * optional sliding window (Gemma-3-style local layers).

Used by the LM family's train/prefill steps; decode uses the pure-jnp path
(one-token query tiles would waste the MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, kv_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(1)
    q = q_ref[0]                       # (Bq, Dh)
    k = k_ref[0]                       # (Bk, Dh)
    v = v_ref[0]                       # (Bk, Dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                   # (Bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)               # (Bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                  # (Bq, Bk)
    # fully-masked rows (e.g. causal rows before any kv) produce exp(-inf
    # - -inf) garbage; zero them explicitly.
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)

    l_new = alpha * l_ref[:, :1] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == kv_blocks - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); Hq % Hkv == 0.

    Returns (B, Hq, Sq, Dh) in q's dtype. window > 0 keeps only keys with
    q_pos - k_pos in [0, window).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0 and k.shape == v.shape
    group = hq // hkv
    scale = dh ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    q_blocks, kv_blocks = sq // block_q, skv // block_k

    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hkv, skv, dh)
    vf = v.reshape(b * hkv, skv, dh)

    def kv_head(h):  # flattened q-head -> flattened kv-head
        return (h // hq) * hkv + (h % hq) // group

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (kv_head(h), j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (kv_head(h), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, dh)
