"""Public attention entry point: Pallas kernel on TPU-shaped problems,
oracle fallback for decode/odd shapes."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_kernel: bool | None = None, interpret: bool | None = None,
              block_q: int = 128, block_k: int = 128):
    """Dispatch between the flash kernel and the jnp oracle.

    Kernel requires Sq/Skv divisible by the block sizes after clamping;
    decode (Sq == 1) always takes the oracle path.
    """
    b, hq, sq, dh = q.shape
    skv = k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    kernel_ok = sq % bq == 0 and skv % bk == 0 and sq > 1
    if use_kernel is None:
        use_kernel = kernel_ok
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window)
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=bq, block_k=bk, interpret=interp)
