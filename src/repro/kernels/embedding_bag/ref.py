"""Pure-jnp EmbeddingBag oracle: take + mask + sum (also the portable
fallback path the recsys model uses off-TPU)."""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_sum_ref(indices: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """indices: (B, L) int32, -1 pads; table: (V, D). Returns (B, D)."""
    valid = (indices >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)  # (B, L, D)
    return jnp.where(valid, rows, 0).sum(axis=1).astype(table.dtype)


def embedding_bag_mean_ref(indices: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    s = embedding_bag_sum_ref(indices, table)
    cnt = jnp.maximum((indices >= 0).sum(axis=1, keepdims=True), 1)
    return (s / cnt).astype(table.dtype)
