"""Jit'd public EmbeddingBag wrappers (kernel on TPU, oracle elsewhere)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_sum
from repro.kernels.embedding_bag.ref import (embedding_bag_mean_ref,
                                             embedding_bag_sum_ref)


def embedding_bag(indices, table, *, mode: str = "sum",
                  interpret: bool | None = None, use_kernel: bool = True):
    """EmbeddingBag(sum|mean) over (B, L) bags of rows of (V, D) table."""
    if not use_kernel:
        if mode == "sum":
            return embedding_bag_sum_ref(indices, table)
        if mode == "mean":
            return embedding_bag_mean_ref(indices, table)
        raise ValueError(mode)
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    s = embedding_bag_sum(indices, table, interpret=interp)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jnp.maximum((indices >= 0).sum(axis=1, keepdims=True), 1)
        return (s / cnt).astype(table.dtype)
    raise ValueError(mode)
