"""EmbeddingBag Pallas TPU kernel — the recsys lookup hot path.

JAX has no native ``EmbeddingBag`` (kernel_taxonomy §B.6): the framework
implements it as gather + ``segment_sum`` (ref.py) and, for the hot path,
as this scalar-prefetch Pallas kernel: bag indices are prefetched to SMEM
and drive the ``index_map`` of the table operand, so each grid step DMAs
exactly one embedding row from HBM into VMEM and accumulates it into the
output row — no (B, L, D) gather intermediate is ever materialized.

Padding convention: ``index < 0`` marks an empty bag slot and contributes
zero (the row DMA still happens — data-independent schedule — but is
masked in the accumulate; on TPU this trades a wasted fetch for a fully
static pipeline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _bag_kernel(idx_ref, table_ref, out_ref):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = (idx_ref[b, l] >= 0).astype(out_ref.dtype)
    out_ref[...] += table_ref[...] * valid


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_sum(indices: jnp.ndarray, table: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """Sum-mode bag lookup. indices: (B, L) int32 (-1 pads); table: (V, D).

    Returns (B, D) in the table dtype (f32 accumulation).
    """
    bsz, bag = indices.shape
    v, d = table.shape
    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,  # indices
            grid=(bsz, bag),
            in_specs=[
                pl.BlockSpec((1, d), lambda b, l, idx: (jnp.maximum(idx[b, l], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda b, l, idx: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(indices, table.astype(jnp.float32))
    return out.astype(table.dtype)
