"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three modules: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd dispatching wrapper) and ref.py (pure-jnp oracle used by the
models off-TPU and by the allclose test sweeps).
"""
