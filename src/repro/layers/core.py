"""Shared neural-net layers: norms, RoPE, chunked online-softmax attention.

Attention note: the Pallas flash kernel (kernels/flash_attention) is the
TPU hot path and is validated in interpret mode; the functions here are the
*portable* XLA implementation used inside the jitted train/serve steps so
the multi-pod dry-run lowers on any backend.  ``chunked_attention`` is an
online-softmax scan over KV chunks — same O(S) memory recipe as flash, so
a 32k-token prefill never materializes an (S, S) score matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * weight + bias).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, H, S, Dh); positions: (S,) shared or (B, S) per-sequence
    (continuous batching serves sequences at different depths)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    if positions.ndim == 1:
        cos, sin = jnp.cos(ang)[None, None], jnp.sin(ang)[None, None]
    else:  # (B, S, half) -> broadcast over heads
        cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------- chunked flash-style attn
def _attn_mask(q_pos, k_pos, valid_len, causal, window):
    mask = k_pos[None, :] < valid_len
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window > 0:
        mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
    return mask


def _attn_fwd_scan(q, k, v, q_offset, kv_len, causal, window, chunk):
    """Online-softmax forward; returns (out, m, l) with softmax stats."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = dh ** -0.5
    nc = skv // chunk

    qg = q.reshape(b, hkv, group, sq, dh)
    kc = jnp.moveaxis(k.reshape(b, hkv, nc, chunk, dh), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hkv, nc, chunk, dh), 2, 0)
    per_batch = (hasattr(q_offset, "ndim") and q_offset.ndim == 1) or \
                (kv_len is not None and hasattr(kv_len, "ndim")
                 and kv_len.ndim == 1)
    if per_batch:  # continuous batching: each sequence at its own depth
        q_off = jnp.asarray(q_offset) * jnp.ones((b,), jnp.int32)
        q_pos = q_off[:, None] + jnp.arange(sq)[None, :]       # (B, Sq)
        vl = (jnp.asarray(skv if kv_len is None else kv_len)
              * jnp.ones((b,), jnp.int32))[:, None]            # (B, 1)
    else:
        q_pos = q_offset + jnp.arange(sq)
        vl = skv if kv_len is None else kv_len

    def step(carry, xs):
        m, l, acc = carry
        j, k_j, v_j = xs
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg.astype(jnp.float32),
                       k_j.astype(jnp.float32)) * scale
        k_pos = j * chunk + jnp.arange(chunk)
        if per_batch:
            mask = k_pos[None, None, :] < vl[:, :, None]       # (B, 1, C)
            mask = jnp.broadcast_to(mask, (b, sq, chunk))
            if causal:
                mask = mask & (q_pos[:, :, None] >= k_pos[None, None, :])
            if window > 0:
                mask = mask & ((q_pos[:, :, None] - k_pos[None, None, :])
                               < window)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
        else:
            mask = _attn_mask(q_pos, k_pos, vl, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        alpha = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_new), 0.0)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhgqc,bhcd->bhgqd", p,
                                           v_j.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (jnp.arange(nc), kc, vc))
    out = acc / jnp.where(l == 0, 1.0, l)
    return out.reshape(b, hq, sq, dh).astype(q.dtype), m, l


def _make_flash_train(causal: bool, window: int, chunk: int):
    """custom_vjp flash attention for the TRAIN path (no cache): the
    backward recomputes per-chunk scores from (q, k, v, out, m, l) instead
    of letting scan save every (Sq x chunk) probability tensor — O(S·Dh)
    residuals instead of O(S^2) (the FlashAttention backward, adapted to an
    XLA scan; see EXPERIMENTS.md §Perf for the memory delta)."""

    @jax.custom_vjp
    def flash(q, k, v):
        out, _, _ = _attn_fwd_scan(q, k, v, 0, None, causal, window, chunk)
        return out

    def fwd(q, k, v):
        out, m, l = _attn_fwd_scan(q, k, v, 0, None, causal, window, chunk)
        return out, (q, k, v, out, m, l)

    def bwd(res, do):
        q, k, v, out, m, l = res
        b, hq, sq, dh = q.shape
        _, hkv, skv, _ = k.shape
        group = hq // hkv
        scale = dh ** -0.5
        nc = skv // chunk
        qg = q.reshape(b, hkv, group, sq, dh).astype(jnp.float32)
        dog = do.reshape(b, hkv, group, sq, dh).astype(jnp.float32)
        og = out.reshape(b, hkv, group, sq, dh).astype(jnp.float32)
        delta = (dog * og).sum(-1, keepdims=True)          # (B,Hkv,G,Sq,1)
        l_safe = jnp.where(l == 0, 1.0, l)
        kc = jnp.moveaxis(k.reshape(b, hkv, nc, chunk, dh), 2, 0)
        vc = jnp.moveaxis(v.reshape(b, hkv, nc, chunk, dh), 2, 0)
        q_pos = jnp.arange(sq)

        def step(dq, xs):
            j, k_j, v_j = xs
            s = jnp.einsum("bhgqd,bhcd->bhgqc", qg,
                           k_j.astype(jnp.float32)) * scale
            k_pos = j * chunk + jnp.arange(chunk)
            mask = _attn_mask(q_pos, k_pos, skv, causal, window)
            p = jnp.exp(s - m) / l_safe
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv_j = jnp.einsum("bhgqc,bhgqd->bhcd", p, dog)
            dp = jnp.einsum("bhgqd,bhcd->bhgqc", dog,
                            v_j.astype(jnp.float32))
            ds = p * (dp - delta) * scale
            dq = dq + jnp.einsum("bhgqc,bhcd->bhgqd", ds,
                                 k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bhgqc,bhgqd->bhcd", ds, qg)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros((b, hkv, group, sq, dh), jnp.float32)
        dq, (dk, dv) = lax.scan(step, dq0, (jnp.arange(nc), kc, vc))
        dk = jnp.moveaxis(dk, 0, 2).reshape(b, hkv, skv, dh)
        dv = jnp.moveaxis(dv, 0, 2).reshape(b, hkv, skv, dh)
        return (dq.reshape(b, hq, sq, dh).astype(q.dtype),
                dk.astype(k.dtype), dv.astype(v.dtype))

    flash.defvjp(fwd, bwd)
    return flash


def decode_attention(q, k, v, *, causal: bool = True, window: int = 0,
                     q_offset=0, kv_len=None):
    """Single-token attention over a (possibly sequence-sharded) cache.

    Direct masked einsum, fp32 softmax: scores are only (B, Hq, Sq, Skv),
    so no chunking is needed, partial scores stay local to each KV shard
    and GSPMD's softmax/combine all-reduces carry (B, Hq, Sq)-sized
    payloads — versus the chunk-scan path whose per-step cache slicing
    re-layouts the whole cache across shards (the gemma3/long_500k
    collective hillclimb, EXPERIMENTS.md §Perf)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = dh ** -0.5
    qg = q.reshape(b, hkv, group, sq, dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    per_batch = getattr(q_offset, "ndim", 0) == 1 or \
        getattr(kv_len, "ndim", 0) == 1
    k_pos = jnp.arange(skv)
    if per_batch:
        q_off = jnp.asarray(q_offset) * jnp.ones((b,), jnp.int32)
        q_pos = q_off[:, None] + jnp.arange(sq)[None, :]         # (B, Sq)
        vl = (jnp.asarray(skv if kv_len is None else kv_len)
              * jnp.ones((b,), jnp.int32))
        mask = k_pos[None, None, :] < vl[:, None, None]
        if causal:
            mask = mask & (q_pos[:, :, None] >= k_pos[None, None, :])
        if window > 0:
            mask = mask & ((q_pos[:, :, None] - k_pos[None, None, :])
                           < window)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        q_pos = q_offset + jnp.arange(sq)
        vl = skv if kv_len is None else kv_len
        mask = _attn_mask(q_pos, k_pos, vl, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(m > NEG_INF / 2, p, 0.0)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / jnp.where(l == 0, 1.0, l),
                   v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      chunk: int = 1024, q_offset=0, kv_len=None):
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh), Hq % Hkv == 0.
    q_offset: global position of q[0] (decode: current length - Sq).
    kv_len: number of valid cache entries (traced ok); None -> Skv.

    The train path (no cache: q_offset == 0, kv_len None) routes through a
    custom-VJP flash implementation with an O(S·Dh)-residual backward.
    Short-query paths (decode) route to the direct einsum.
    """
    skv = k.shape[2]
    sq = q.shape[2]
    if sq <= 8:  # decode: scores are (B,H,Sq,Skv) — no chunking needed
        return decode_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, kv_len=kv_len)
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    if kv_len is None and isinstance(q_offset, int) and q_offset == 0:
        return _make_flash_train(causal, window, chunk)(q, k, v)
    out, _, _ = _attn_fwd_scan(q, k, v, q_offset, kv_len, causal, window,
                               chunk)
    return out


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None):
    """Mean token CE in fp32. logits (..., V); labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
