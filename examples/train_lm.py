"""End-to-end LM training driver with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Trains a ~10M-parameter dense transformer (the CPU-feasible stand-in; the
same Trainer + build_bundle path drives the full assigned configs on real
meshes via launch/train.py) for a few hundred steps on synthetic tokens,
checkpointing every 50 steps, then kills and resumes to demonstrate the
restart contract.
"""

import argparse

import numpy as np

from repro.configs.base import ArchSpec, LMShape, TransformerConfig
from repro.launch.steps import StepBundle, _lm_bundle  # noqa: SLF001
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

CFG = TransformerConfig(
    name="demo-10m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
    head_dim=32, d_ff=1024, vocab=4096, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if args.ckpt_dir is None:
        import tempfile
        args.ckpt_dir = tempfile.mkdtemp("repro_train_lm")

    n_params = CFG.param_count()
    print(f"model: {CFG.name} params={n_params/1e6:.1f}M")
    spec = ArchSpec("demo", "lm", CFG, CFG, "example")
    shape = LMShape("train_demo", "train", args.seq, args.batch)
    bundle = _lm_bundle(spec, shape, CFG,
                        AdamWConfig(lr=1e-3, warmup_steps=20,
                                    total_steps=args.steps))

    half = args.steps // 2
    t1 = Trainer(bundle, TrainerConfig(num_steps=half, ckpt_every=50,
                                       log_every=20, ckpt_dir=args.ckpt_dir))
    t1.run()
    print(f"-- simulated preemption at step {half}; resuming --")
    t2 = Trainer(bundle, TrainerConfig(num_steps=args.steps, ckpt_every=50,
                                       log_every=20, ckpt_dir=args.ckpt_dir))
    t2.run(resume=True)

    losses = [(m["step"], m["loss"]) for m in t1.metrics_log + t2.metrics_log
              if "loss" in m]
    print("step,loss")
    for s, l in losses:
        print(f"{s},{l:.4f}")
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first


if __name__ == "__main__":
    main()
