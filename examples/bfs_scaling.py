"""End-to-end driver for the paper's experiment grid (paper §3-4).

    PYTHONPATH=src python examples/bfs_scaling.py [--full]

Default runs reduced vertex counts suitable for the CPU container; --full
uses the paper's exact sizes (4M-vertex star, 100k ER/small-world) — the
same code path, just bigger host arrays.  For every workload it prints the
strong-scaling table (measured compute split + HLO-validated comm model)
for the baseline and optimized exchanges, reproducing the shapes of paper
figs. 4, 6 and 8 including the 64-processor upturn for the baseline.
"""

import argparse
import time

from repro.configs.base import BFS_WORKLOADS
from repro.core import BFSOptions, plan
from repro.core import exchange as ex
from repro.graphs import generate, shard_graph
from repro.launch.hlo_stats import ICI_BW

REDUCED = {"star_4m": 400_000, "erdos_renyi_100k": 100_000,
           "small_world_100k": 100_000, "rmat_1m": 131_072}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact sizes (4M star etc.)")
    args = ap.parse_args()

    for wl in BFS_WORKLOADS:
        n = wl.n_vertices if args.full else REDUCED[wl.name]
        kw = dict(wl.gen_kwargs)
        t0 = time.time()
        src, dst = generate(wl.graph, n, seed=0, **kw)
        g = shard_graph(src, dst, n, p=1)
        gen_s = time.time() - t0
        print(f"\n== {wl.name}: n={n} edges={src.shape[0]} "
              f"(generated in {gen_s:.1f}s, chunked per paper §3.1) ==")
        opts = BFSOptions(mode="auto", queue_cap=1 << 15)
        t0 = time.time()
        engine = plan(g, opts).compile()
        compile_s = time.time() - t0
        engine.run([0])                       # first dispatch (warm)
        t0 = time.time()
        res = engine.run([0])
        step_s = time.time() - t0             # device-only traversal time
        stats = res.stats()
        print(f"  BFS: levels={stats.levels} visited={stats.visited} "
              f"modes={stats.mode_counts} compile={compile_s:.2f}s "
              f"run={step_s:.2f}s (compile paid once per graph/options)")
        print(f"  {'p':>4s} {'baseline_total':>15s} {'optimized_total':>16s} "
              f"{'ratio':>6s}")
        for p in (1, 2, 4, 8, 16, 32, 64):
            comp = step_s / p
            base = comp + stats.levels * ex.dense_level_bytes(
                "allgather_merge", g.part.n, p) / ICI_BW
            opt = comp + stats.levels * ex.dense_level_bytes(
                "alltoall_direct", g.part.n, p) / ICI_BW
            print(f"  {p:>4d} {base:>14.4f}s {opt:>15.4f}s "
                  f"{base/opt:>6.2f}")


if __name__ == "__main__":
    main()
