"""Continuous-batching LM serving demo.

    PYTHONPATH=src python examples/serve_lm.py

Serves a small model with the production Server (per-slot sequence depths,
slot recycling) over a burst of batched requests and reports throughput.
"""

import time

import jax
import numpy as np

from repro.configs.base import TransformerConfig
from repro.models import transformer as tf
from repro.serve.batcher import Request, Server

CFG = TransformerConfig(
    name="demo-serve", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=512, vocab=1024, dtype="float32")


def main():
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    srv = Server(CFG, params, batch_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab, rng.integers(3, 9))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
            for i in range(10)]
    for r in reqs:
        srv.submit(r)
    t0 = time.time()
    done = srv.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on 1 CPU core)")
    for r in done[:4]:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} out={r.out}")
    assert len(done) == len(reqs)


if __name__ == "__main__":
    main()
