"""Quickstart: distributed BFS on all three paper graph families.

    PYTHONPATH=src python examples/quickstart.py

Runs the 1-D-partitioned engine in every frontier mode on a small-world,
an Erdős-Rényi and a star graph through the compile-once lifecycle
(``plan(...).compile()`` then ``engine.run(source)``), validates against
the serial oracle, and prints the per-mode communication volumes — the
paper's §5 story in one screen.  Each engine is reused for a second
traversal from a different source to show that fresh sources are
device-only work (zero retraces).
"""

import numpy as np

from repro.core import BFSOptions, plan
from repro.core.ref import INF, bfs_reference
from repro.graphs import generate, shard_graph


def main():
    n = 20_000
    for kind, kw in (("small_world", {"k": 8, "beta": 0.1}),
                     ("erdos_renyi", {"avg_degree": 8.0}),
                     ("star", {})):
        src, dst = generate(kind, n, seed=0, **kw)
        g = shard_graph(src, dst, n, p=1)
        want = bfs_reference(src, dst, n, [0])
        want2 = bfs_reference(src, dst, n, [n // 2])
        print(f"\n== {kind}: n={n} directed_edges={src.shape[0]} ==")
        for mode in ("dense", "queue", "auto"):
            for strat in (("allgather_merge", "baseline [2]"),
                          ("alltoall_direct", "paper-optimized")):
                opts = BFSOptions(mode=mode, dense_exchange=strat[0],
                                  queue_exchange=strat[0],
                                  queue_cap=1 << 14)
                engine = plan(g, opts).compile()
                res = engine.run([0])
                stats = res.stats()
                ok = np.array_equal(res.dist_host, want)
                # reuse: new source, same executable, no retrace
                ok &= np.array_equal(engine.run([n // 2]).dist_host, want2)
                ok &= engine.trace_count == engine.compile_traces
                print(f"  mode={mode:6s} exchange={strat[1]:16s} "
                      f"levels={stats.levels:3d} "
                      f"visited={stats.visited:6d} "
                      f"comm_bytes/chip={stats.comm_bytes:12.0f} "
                      f"{'OK' if ok else 'MISMATCH'}")
        reach = int((want < INF).sum())
        print(f"  reachable from source: {reach}/{n}")


if __name__ == "__main__":
    main()
