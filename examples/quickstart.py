"""Quickstart: distributed BFS on all three paper graph families.

    PYTHONPATH=src python examples/quickstart.py

Runs the 1-D-partitioned engine in every frontier mode on a small-world,
an Erdős-Rényi and a star graph, validates against the serial oracle, and
prints the per-mode communication volumes — the paper's §5 story in one
screen.
"""

import numpy as np

from repro.core import BFSOptions, bfs
from repro.core.ref import INF, bfs_reference
from repro.graphs import generate, shard_graph


def main():
    n = 20_000
    for kind, kw in (("small_world", {"k": 8, "beta": 0.1}),
                     ("erdos_renyi", {"avg_degree": 8.0}),
                     ("star", {})):
        src, dst = generate(kind, n, seed=0, **kw)
        g = shard_graph(src, dst, n, p=1)
        want = bfs_reference(src, dst, n, [0])
        print(f"\n== {kind}: n={n} directed_edges={src.shape[0]} ==")
        for mode in ("dense", "queue", "auto"):
            for strat in (("allgather_merge", "baseline [2]"),
                          ("alltoall_direct", "paper-optimized")):
                opts = BFSOptions(mode=mode, dense_exchange=strat[0],
                                  queue_exchange=strat[0]
                                  if strat[0] in ("allgather_merge",
                                                  "alltoall_direct")
                                  else "alltoall_direct",
                                  queue_cap=1 << 14)
                dist, stats = bfs(g, [0], opts=opts)
                ok = np.array_equal(dist, want)
                print(f"  mode={mode:6s} exchange={strat[1]:16s} "
                      f"levels={stats.levels:3d} "
                      f"visited={stats.visited:6d} "
                      f"comm_bytes/chip={stats.comm_bytes:12.0f} "
                      f"{'OK' if ok else 'MISMATCH'}")
        reach = int((want < INF).sum())
        print(f"  reachable from source: {reach}/{n}")


if __name__ == "__main__":
    main()
