"""GNN training: full-batch GCN + sampled-minibatch GraphSAGE-style run.

    PYTHONPATH=src python examples/gnn_train.py
"""

import tempfile

import numpy as np

from repro.configs.base import get_arch
from repro.data.pipeline import graph_minibatch_stream
from repro.graphs import csr_from_coo, erdos_renyi
from repro.graphs.sampler import NeighborSampler
from repro.launch.steps import build_bundle
from repro.train.trainer import Trainer, TrainerConfig


def full_batch():
    spec = get_arch("gcn_cora")
    b = build_bundle(spec, "full_graph_sm", reduced=True)
    t = Trainer(b, TrainerConfig(num_steps=30, ckpt_every=10, log_every=5,
                                 ckpt_dir=tempfile.mkdtemp("repro_gcn")))
    t.run()
    losses = [m["loss"] for m in t.metrics_log if "loss" in m]
    print(f"gcn full-batch: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


def sampled_minibatch():
    import jax
    from repro.models.gnn import models as gnn
    from repro.optim.adamw import AdamWConfig
    from repro.optim.adamw import apply_updates, init_state

    spec = get_arch("gatedgcn")
    cfg = spec.reduced
    # a reddit-like synthetic graph + the real neighbor sampler
    n = 5_000
    src, dst = erdos_renyi(n, avg_degree=20, seed=0)
    indptr, indices = csr_from_coo(src, dst, n)
    sampler = NeighborSampler(indptr, indices)
    stream = graph_minibatch_stream(sampler, batch_nodes=32, fanouts=(5, 3),
                                    n_pad=1024, e_pad=1024, d_feat=16, seed=0)
    params = gnn.init_params(cfg, 16, jax.random.PRNGKey(0))
    opt = init_state(params)
    ocfg = AdamWConfig(lr=3e-3)

    import jax.numpy as jnp
    rng = np.random.default_rng(0)

    @jax.jit
    def train_step(params, opt, batch):
        def loss_fn(p):
            return gnn.loss_fn(cfg, p, batch)
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = apply_updates(ocfg, params, g, opt)
        return params, opt, l

    losses = []
    for i in range(20):
        _, batch = next(stream)
        batch = {k: jnp.asarray(v) for k, v in batch.items()
                 if k != "global_ids"}
        batch["targets"] = jnp.asarray(
            rng.standard_normal((batch["node_feats"].shape[0], cfg.d_out))
            .astype(np.float32))
        params, opt, l = train_step(params, opt, batch)
        losses.append(float(l))
    stream.close()
    print(f"gatedgcn sampled-minibatch: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} over {len(losses)} sampled subgraphs")


if __name__ == "__main__":
    full_batch()
    sampled_minibatch()
